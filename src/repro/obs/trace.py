"""Trace-safe structured span tracer (`$SPIN_TRACE`).

The recursion, the planner, the worker pool, and the serving tick loop all
emit *spans* — `{name, kind, t0, t1, attrs, thread}` records — into one
process-global `SpanTracer`. Three properties define the design:

  * **Zero overhead when off.** Every instrumentation site is guarded by a
    single attribute read (`if TRACER.enabled:`); with `SPIN_TRACE` unset no
    span object is built, no attribute dict is materialized, and — the hard
    requirement — no `block_until_ready`/host sync is ever inserted on the
    jitted hot path. `tests/test_obs_overhead.py` proves the compiled
    program is identical with tracing on and off.
  * **Trace-time emission for jitted code.** The whole Algorithm-2
    recursion compiles into ONE XLA program, so there are no per-level
    Python events at *run* time — the per-level spans are emitted while JAX
    traces the recursion (once per jit cache entry). Their durations
    measure trace cost; their *structure* (level, grid, engine) is the
    recursion's, and is what the op-count-oracle tests check. A re-run that
    hits the jit cache emits no new recursion spans — by design.
  * **Profiler bridging.** When tracing is on, spans open a
    `jax.profiler.TraceAnnotation` (host-side spans) or a
    `jax.named_scope` (inside-jit spans), so a captured profile shows the
    same names this module records.

Every span is also mirrored into the flight recorder's ring buffer
(`repro.obs.flight`) so a post-mortem dump carries the trace tail.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from typing import Any, Iterator, Optional

from repro import envconfig

__all__ = ["Span", "SpanTracer", "TRACER", "tracer", "trace_enabled",
           "tracing", "refresh", "TRACE_ENV", "TRACE_DIR_ENV"]

TRACE_ENV = "SPIN_TRACE"
TRACE_DIR_ENV = "SPIN_TRACE_DIR"


@dataclasses.dataclass
class Span:
    """One structured event. Point events have t1 == t0."""

    name: str
    kind: str                 # "recursion_level" | "planner_decision" | ...
    t0: float
    t1: float
    attrs: dict[str, Any]
    thread: int

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "kind": self.kind, "t0": self.t0,
                "t1": self.t1, "duration_s": self.duration_s,
                "thread": self.thread, **self.attrs}


class SpanTracer:
    """Bounded in-memory span store with an `enabled` fast-path guard.

    `enabled` is a plain attribute, not a property: the disabled-path cost
    at every instrumentation site is one LOAD_ATTR. Flipping it is done via
    `tracing(...)` (tests) or `refresh()` (env changes mid-process).
    """

    def __init__(self, *, enabled: bool | None = None, capacity: int = 65536,
                 clock=time.perf_counter):
        self.enabled = (envconfig.env_bool(TRACE_ENV)
                        if enabled is None else bool(enabled))
        self.capacity = capacity
        self._clock = clock
        self._lock = threading.Lock()
        self._spans: list[Span] = []
        self._dropped = 0

    # -- recording -----------------------------------------------------------

    def _store(self, span: Span) -> None:
        with self._lock:
            if len(self._spans) >= self.capacity:
                self._dropped += 1
                return
            self._spans.append(span)
        # Mirror into the flight recorder so crash dumps carry the tail.
        # Merged dict, attrs last: an event that carries its own name or
        # duration_s attr (e.g. worker.done's shard duration) must override
        # the span-level value, not raise a duplicate-kwarg TypeError.
        from . import flight

        flight.recorder().record(span.kind, **{
            "name": span.name, "duration_s": span.duration_s, **span.attrs})

    def event(self, name: str, kind: str, **attrs) -> Optional[Span]:
        """Record a point event (no duration). No-op when disabled."""
        if not self.enabled:
            return None
        now = self._clock()
        span = Span(name=name, kind=kind, t0=now, t1=now, attrs=attrs,
                    thread=threading.get_ident())
        self._store(span)
        return span

    @contextlib.contextmanager
    def span(self, name: str, kind: str, *, named_scope: bool = False,
             **attrs) -> Iterator[Optional[Span]]:
        """Timed span context. `named_scope=True` bridges via
        `jax.named_scope` (legal inside jit tracing — pure metadata);
        the default bridges via `jax.profiler.TraceAnnotation` (host-side
        only). Call sites must still guard with `if TRACER.enabled:` —
        entering a contextmanager is NOT free."""
        if not self.enabled:
            yield None
            return
        ctx = _named_scope(name) if named_scope else _trace_annotation(name)
        t0 = self._clock()
        span = Span(name=name, kind=kind, t0=t0, t1=t0, attrs=attrs,
                    thread=threading.get_ident())
        try:
            with ctx:
                yield span
        finally:
            span.t1 = self._clock()
            self._store(span)

    # -- reading -------------------------------------------------------------

    def spans(self, kind: str | None = None, name: str | None = None
              ) -> list[Span]:
        with self._lock:
            out = list(self._spans)
        if kind is not None:
            out = [s for s in out if s.kind == kind]
        if name is not None:
            out = [s for s in out if s.name == name]
        return out

    @property
    def dropped(self) -> int:
        return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._dropped = 0

    def refresh(self) -> bool:
        """Re-read $SPIN_TRACE (for processes that flip it mid-run)."""
        self.enabled = envconfig.env_bool(TRACE_ENV)
        return self.enabled


def _trace_annotation(name: str):
    try:
        import jax

        return jax.profiler.TraceAnnotation(name)
    except Exception:                                  # pragma: no cover
        return contextlib.nullcontext()


def _named_scope(name: str):
    try:
        import jax

        return jax.named_scope(name)
    except Exception:                                  # pragma: no cover
        return contextlib.nullcontext()


# The process-global tracer every subsystem guards on. Import-time env read
# only — no jax import, no side effects.
TRACER = SpanTracer()


def tracer() -> SpanTracer:
    return TRACER


def trace_enabled() -> bool:
    return TRACER.enabled


def refresh() -> bool:
    return TRACER.refresh()


@contextlib.contextmanager
def tracing(enabled: bool = True, *, clear: bool = False) -> Iterator[SpanTracer]:
    """Temporarily flip the global tracer (tests, benchmark sections).

    `clear=True` empties the span store on entry so assertions see only the
    spans of the guarded region. The previous enabled state is restored.
    """
    prev = TRACER.enabled
    if clear:
        TRACER.clear()
    TRACER.enabled = bool(enabled)
    try:
        yield TRACER
    finally:
        TRACER.enabled = prev
