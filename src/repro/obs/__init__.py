"""Unified observability layer (DESIGN.md §13).

Four pieces, one import surface:

  * `trace`    — structured span tracer ($SPIN_TRACE), zero-overhead off.
  * `registry` — counters/gauges/histograms; Prometheus text + JSON export.
  * `flight`   — bounded ring-buffer flight recorder, JSONL dumps on
                 failures to $SPIN_TRACE_DIR.
  * `ledger`   — modeled-vs-measured cost ledger feeding `fit_scale`
                 calibration and observed straggle rates back to the planner.

Import-light by contract: importing `repro.obs` must not import jax (the
tracer and registry are consulted by modules that run before jax config).
"""

from . import flight, ledger, registry, trace
from .flight import FlightRecorder, recorder
from .ledger import CostLedger, LedgerEntry, StraggleStats
from .ledger import ledger as cost_ledger
from .registry import (Counter, Gauge, Histogram, MetricsRegistry,
                       default_registry)
from .trace import TRACER, Span, SpanTracer, trace_enabled, tracing

__all__ = [
    "trace", "registry", "flight", "ledger",
    "TRACER", "Span", "SpanTracer", "trace_enabled", "tracing",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "default_registry",
    "FlightRecorder", "recorder",
    "CostLedger", "LedgerEntry", "StraggleStats", "cost_ledger",
]
