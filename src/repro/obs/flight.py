"""Flight recorder: a bounded ring buffer of structured events, dumped as
JSONL on failures.

Chaos-test postmortems previously reconstructed what happened from pytest
output; now the last N events — worker starts/overdue/retries/failures,
degraded-mode transitions, failed batches, plus every tracer span (the
tracer mirrors into this ring) — are always being recorded in memory, and a
failure site calls `dump(reason)` to write them to
`$SPIN_TRACE_DIR/flight-<reason>-<pid>-<seq>.jsonl`. With SPIN_TRACE_DIR
unset, `dump` is a silent no-op: recording stays cheap (one deque append
under a lock, host-side only — never on the jitted hot path) and nothing
touches the filesystem.

Dump format: line 1 is a header `{"flight_dump": reason, "events": N,
"ts": unix_time, "pid": …}`; each following line is one event oldest-first
`{"ts", "kind", ...attrs}`.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from collections import deque
from typing import Optional

from repro import envconfig

__all__ = ["FlightRecorder", "recorder", "set_recorder", "DUMP_DIR_ENV"]

DUMP_DIR_ENV = "SPIN_TRACE_DIR"


class FlightRecorder:
    """Thread-safe ring buffer of {ts, kind, **attrs} events."""

    def __init__(self, capacity: int | None = None, *, clock=time.time):
        if capacity is None:
            capacity = envconfig.env_int("SPIN_FLIGHT_CAPACITY", 512)
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._clock = clock
        self._lock = threading.Lock()
        self._events: deque[dict] = deque(maxlen=capacity)
        self._seq = 0
        self.dumps: list[str] = []          # paths written this process

    def record(self, kind: str, **attrs) -> None:
        evt = {"ts": self._clock(), "kind": kind}
        for k, v in attrs.items():
            evt[k] = _jsonable(v)
        with self._lock:
            self._events.append(evt)

    def events(self, kind: str | None = None) -> list[dict]:
        with self._lock:
            out = list(self._events)
        if kind is not None:
            out = [e for e in out if e["kind"] == kind]
        return out

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def dump(self, reason: str, directory: str | None = None
             ) -> Optional[str]:
        """Write the ring as JSONL; returns the path, or None when no dump
        directory is configured. Never raises: a failing postmortem write
        must not mask the failure being recorded."""
        directory = directory or envconfig.env_str(DUMP_DIR_ENV)
        if not directory:
            return None
        with self._lock:
            events = list(self._events)
            self._seq += 1
            seq = self._seq
        safe = "".join(c if c.isalnum() or c in "-_" else "-"
                       for c in reason) or "dump"
        path = os.path.join(directory,
                            f"flight-{safe}-{os.getpid()}-{seq}.jsonl")
        try:
            os.makedirs(directory, exist_ok=True)
            with open(path, "w") as f:
                f.write(json.dumps({"flight_dump": reason,
                                    "events": len(events),
                                    "ts": self._clock(),
                                    "pid": os.getpid()}) + "\n")
                for evt in events:
                    f.write(json.dumps(evt) + "\n")
        except OSError:                                # pragma: no cover
            return None
        with self._lock:
            self.dumps.append(path)
        return path


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple, set, frozenset)):
        return [_jsonable(x) for x in v]
    with contextlib.suppress(TypeError, ValueError):
        return float(v)                    # numpy scalars and friends
    return repr(v)


_recorder = FlightRecorder()


def recorder() -> FlightRecorder:
    """The process-global flight recorder."""
    return _recorder


def set_recorder(rec: FlightRecorder) -> FlightRecorder:
    """Swap the global recorder (hermetic tests); returns the previous."""
    global _recorder
    prev, _recorder = _recorder, rec
    return prev
