"""Metrics registry: counters / gauges / histograms with labels, exported
as Prometheus text exposition or JSON.

One process-global `default_registry()` is the dashboard surface: the
serving layer's `ServiceMetrics` mirrors its counters and latency
reservoirs into it, and coded execution publishes each `CodedRunReport`
(used ranks, stragglers, attempts, median shard time) — the straggle
history that previously died on the caller's stack. Benchmarks export the
registry into their JSON reports (`BENCH_serve.json` / `BENCH_straggler.json`
gain a `"metrics"` section) and a scraper can consume `prometheus_text()`.

Naming convention (DESIGN.md §13): `spin_<subsystem>_<noun>[_unit]`, e.g.
`spin_serve_requests_total`, `spin_coded_stragglers_total`,
`spin_serve_latency_seconds`. Counters end in `_total`; durations are
seconds. Labels are sparse — a handful of bounded-cardinality keys (path,
reason, stage), never ids.

Everything here is host-side Python over plain dicts under one lock per
metric — safe to call from WorkerPool daemon threads and snapshot_async
background threads concurrently with tick-loop reads.
"""

from __future__ import annotations

import threading
from typing import Iterable, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_registry", "set_default_registry", "DEFAULT_BUCKETS"]

# Latency-oriented default buckets (seconds): 100µs … ~100s, log-spaced.
DEFAULT_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1,
                   1.0, 3.0, 10.0, 30.0, 100.0)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class _Metric:
    def __init__(self, name: str, help_: str):
        if not name.replace("_", "").isalnum():
            raise ValueError(f"metric name must be [a-z0-9_], got {name!r}")
        self.name = name
        self.help = help_
        self._lock = threading.Lock()


class Counter(_Metric):
    """Monotonic counter, optionally labeled."""

    kind = "counter"

    def __init__(self, name: str, help_: str = ""):
        super().__init__(name, help_)
        self._values: dict[tuple, float] = {}

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        k = _label_key(labels)
        with self._lock:
            self._values[k] = self._values.get(k, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(_label_key(labels), 0.0)

    def collect(self) -> dict:
        with self._lock:
            return {_label_str(k): v for k, v in sorted(self._values.items())}


class Gauge(_Metric):
    """Last-write-wins instantaneous value, optionally labeled."""

    kind = "gauge"

    def __init__(self, name: str, help_: str = ""):
        super().__init__(name, help_)
        self._values: dict[tuple, float] = {}

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._values[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        k = _label_key(labels)
        with self._lock:
            self._values[k] = self._values.get(k, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(_label_key(labels), 0.0)

    def collect(self) -> dict:
        with self._lock:
            return {_label_str(k): v for k, v in sorted(self._values.items())}


class Histogram(_Metric):
    """Prometheus-style histogram: cumulative bucket counts + sum + count."""

    kind = "histogram"

    def __init__(self, name: str, help_: str = "",
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, help_)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        # per label-set: [per-bucket counts..., +Inf count], sum
        self._counts: dict[tuple, list[int]] = {}
        self._sums: dict[tuple, float] = {}

    def observe(self, value: float, **labels) -> None:
        v = float(value)
        k = _label_key(labels)
        with self._lock:
            counts = self._counts.setdefault(k, [0] * (len(self.buckets) + 1))
            for i, bound in enumerate(self.buckets):
                if v <= bound:
                    counts[i] += 1
                    break
            else:
                counts[-1] += 1
            self._sums[k] = self._sums.get(k, 0.0) + v

    def summary(self, **labels) -> dict:
        k = _label_key(labels)
        with self._lock:
            counts = list(self._counts.get(k, []))
            total = sum(counts)
            return {"count": total, "sum": self._sums.get(k, 0.0),
                    "mean": (self._sums.get(k, 0.0) / total) if total else 0.0}

    def collect(self) -> dict:
        with self._lock:
            out = {}
            for k, counts in sorted(self._counts.items()):
                cum, rows = 0, {}
                for bound, c in zip(self.buckets, counts):
                    cum += c
                    rows[f"le={bound:g}"] = cum
                rows["le=+Inf"] = cum + counts[-1]
                out[_label_str(k)] = {"buckets": rows,
                                      "sum": self._sums.get(k, 0.0),
                                      "count": cum + counts[-1]}
            return out


class MetricsRegistry:
    """Named metric store with get-or-create accessors and two exporters."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _get_or_create(self, cls, name: str, help_: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help_, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help_: str = "") -> Counter:
        return self._get_or_create(Counter, name, help_)

    def gauge(self, name: str, help_: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help_)

    def histogram(self, name: str, help_: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help_, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    # -- exporters -----------------------------------------------------------

    def to_json(self) -> dict:
        """JSON-ready nested dict: {name: {type, help, values}}."""
        with self._lock:
            metrics = list(self._metrics.values())
        return {m.name: {"type": m.kind, "help": m.help,
                         "values": m.collect()} for m in metrics}

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (v0.0.4)."""
        with self._lock:
            metrics = sorted(self._metrics.values(), key=lambda m: m.name)
        lines: list[str] = []
        for m in metrics:
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            collected = m.collect()
            if isinstance(m, Histogram):
                for labels, row in collected.items():
                    base = labels[1:-1] if labels else ""
                    for le, cum in row["buckets"].items():
                        bound = le.split("=", 1)[1]
                        inner = (base + "," if base else "") + f'le="{bound}"'
                        lines.append(
                            f"{m.name}_bucket{{{inner}}} {cum}")
                    lines.append(f"{m.name}_sum{labels} {row['sum']}")
                    lines.append(f"{m.name}_count{labels} {row['count']}")
            else:
                for labels, v in collected.items():
                    lines.append(f"{m.name}{labels} {v}")
        return "\n".join(lines) + ("\n" if lines else "")


_default = MetricsRegistry()
_default_lock = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The process-global registry (dashboards, benchmark exports)."""
    return _default


def set_default_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the global registry (hermetic tests); returns the previous one."""
    global _default
    with _default_lock:
        prev, _default = _default, registry
    return prev
