"""Cost ledger: modeled cost vs measured wall clock, fed back to the planner.

SPIN's central empirical claim is that the Lemma-4.1 theoretical running
times "match closely with the empirically observed wall clock" — the paper's
Fig. 4. This module closes that loop *in production*, not just in a
benchmark sweep:

  * every traced planned solve records a `LedgerEntry` pairing the plan's
    modeled seconds (`spin_cost` / `strassen_cost` / `tpu_roofline_cost`,
    via `planner.autotune.predict_cost`) with the measured wall clock of
    the same execution (entries are recorded only when `SPIN_TRACE` is on,
    because measuring requires a `block_until_ready` the untraced hot path
    must never pay);
  * `flush_calibration()` turns accumulated default-axis entries into
    `costmodel.fit_scale` constants and persists them through
    `PlanCache.put_calibration` — production solves now calibrate the
    planner the way `autotune`'s microbenchmarks do (ROADMAP item 3's
    observability gap);
  * every coded run's `CodedRunReport` is folded into per-process straggle
    statistics, and `observed_straggler_prob()` replaces the static
    `CodedConfig.straggler_prob` guess inside `plan_redundancy` once enough
    runs are on record (ROADMAP item 2's gap). Coded-run recording is
    always on — the report already exists; folding it is a few dict ops.

`benchmarks/fig4_theory.py` reports the ledger's modeled/measured ratio per
traced point next to its offline fit — the theory-vs-practice U-shape from
live entries.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Optional

__all__ = ["LedgerEntry", "StraggleStats", "CostLedger", "ledger",
           "set_ledger", "MIN_CODED_RUNS"]

# Observed straggle rates are trusted only past this many coded runs —
# below it one unlucky run would swing `plan_redundancy` wildly.
MIN_CODED_RUNS = 3


@dataclasses.dataclass
class LedgerEntry:
    """One traced solve: what the model said vs what the clock said."""

    kind: str                  # "inverse" | "solve"
    n: int
    b: int                     # block grid
    block_size: int
    leaf_solver: str
    engine: str
    dtype: str
    backend: str
    predicted_s: Optional[float]
    measured_s: float
    source: str = "traced"     # provenance of the prediction

    @property
    def ratio(self) -> Optional[float]:
        """modeled / measured — 1.0 is a perfect model."""
        if not self.predicted_s or self.measured_s <= 0:
            return None
        return self.predicted_s / self.measured_s

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ratio"] = self.ratio
        return d


@dataclasses.dataclass
class StraggleStats:
    """Per-process straggle history folded from CodedRunReports."""

    runs: int = 0
    worker_slots: int = 0      # total worker executions observed
    stragglers: int = 0        # workers declared overdue
    failures: int = 0          # workers that exhausted retries
    extra_attempts: int = 0    # retries beyond the first attempt
    per_rank: dict = dataclasses.field(default_factory=dict)

    def straggler_prob(self) -> float:
        if self.worker_slots == 0:
            return 0.0
        # Failures count as stragglers for redundancy planning: a dead
        # worker delays completion at least as much as an overdue one.
        return (self.stragglers + self.failures) / self.worker_slots


class CostLedger:
    """Thread-safe store of LedgerEntries + coded-run straggle stats."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._entries: list[LedgerEntry] = []
        self._straggle = StraggleStats()

    # -- modeled-vs-measured entries -----------------------------------------

    def record(self, entry: LedgerEntry) -> None:
        with self._lock:
            if len(self._entries) < self.capacity:
                self._entries.append(entry)

    def record_solve(self, *, kind: str, n: int, plan, backend: str,
                     dtype: str, measured_s: float,
                     predicted_s: float | None = None) -> LedgerEntry:
        """Record one traced planned execution from its Plan + wall time."""
        entry = LedgerEntry(
            kind=kind, n=int(n), b=plan.grid(int(n)),
            block_size=plan.block_size, leaf_solver=plan.leaf_solver,
            engine=plan.multiply_engine, dtype=dtype, backend=backend,
            predicted_s=(predicted_s if predicted_s is not None
                         else plan.predicted_s),
            measured_s=float(measured_s))
        self.record(entry)
        return entry

    def entries(self, kind: str | None = None) -> list[LedgerEntry]:
        with self._lock:
            out = list(self._entries)
        if kind is not None:
            out = [e for e in out if e.kind == kind]
        return out

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._straggle = StraggleStats()

    def summary(self) -> dict:
        """Aggregate model quality: count + mean/worst modeled/measured
        ratio, plus the straggle statistics."""
        entries = self.entries()
        ratios = [e.ratio for e in entries if e.ratio is not None]
        with self._lock:
            straggle = dataclasses.asdict(self._straggle)
        straggle["straggler_prob"] = self._straggle.straggler_prob()
        return {
            "entries": len(entries),
            "with_prediction": len(ratios),
            "mean_ratio": (sum(ratios) / len(ratios)) if ratios else None,
            "min_ratio": min(ratios) if ratios else None,
            "max_ratio": max(ratios) if ratios else None,
            "straggle": straggle,
        }

    # -- calibration feedback (ROADMAP item 3) -------------------------------

    def calibration_points(self, kind: str = "inverse"
                           ) -> dict[tuple[int, str], dict[int, float]]:
        """Default-axis {(n, dtype): {b: best measured seconds}} groups.

        Same axis rule as `autotune._calibration_points`: linalg leaves,
        einsum engine — entries whose leaf/engine multipliers are 1.0, so
        the fit recovers the *shared* constants. Best (min) per grid, for
        the same reason `measure_plans` takes min: noise is additive.
        """
        groups: dict[tuple[int, str], dict[int, float]] = {}
        for e in self.entries(kind):
            if e.leaf_solver != "linalg" or e.engine != "einsum":
                continue
            pts = groups.setdefault((e.n, e.dtype), {})
            pts[e.b] = min(pts.get(e.b, float("inf")), e.measured_s)
        return groups

    def flush_calibration(self, cache=None, *, min_grids: int = 3,
                          kind: str = "inverse") -> dict | None:
        """Fit cost-model constants from recorded entries and persist them.

        Needs >= `min_grids` distinct block grids for one (n, dtype) on a
        non-TPU backend (the TPU roofline has no fitted constants). Returns
        the new constants, or None when no group qualifies.
        """
        from repro.core.costmodel import fit_scale, spin_cost
        from repro.planner.cache import default_cache
        from repro.planner.plan import signature_for

        best = None
        for (n, dtype), pts in self.calibration_points(kind).items():
            if len(pts) >= min_grids and (best is None
                                          or len(pts) > len(best[2])):
                best = (n, dtype, pts)
        if best is None:
            return None
        n, dtype, pts = best
        sig = signature_for(kind, n, dtype)
        if sig.backend == "tpu":
            return None
        fit = fit_scale(spin_cost, pts, n=n, cores=sig.cores)
        constants = {"t_flop": fit.t_flop, "t_leaf": fit.t_leaf,
                     "t_block_op": fit.t_block_op, "t_elem": fit.t_elem}
        (cache or default_cache()).put_calibration(sig, constants)
        return constants

    # -- straggle feedback (ROADMAP item 2) ----------------------------------

    def record_coded_run(self, report, workers: int) -> None:
        """Fold one CodedRunReport into the straggle statistics."""
        with self._lock:
            s = self._straggle
            s.runs += 1
            s.worker_slots += int(workers)
            s.stragglers += len(report.stragglers)
            s.failures += len(report.failed)
            s.extra_attempts += sum(max(a - 1, 0)
                                    for a in report.attempts.values())
            for rank in report.stragglers:
                key = str(rank)
                s.per_rank[key] = s.per_rank.get(key, 0) + 1

    def observed_straggler_prob(self, default: float,
                                *, min_runs: int = MIN_CODED_RUNS) -> float:
        """Observed per-worker straggle rate, or `default` below min_runs.

        A zero observed rate is floored at half the default rather than 0:
        `plan_redundancy` at p=0 would drop ALL redundancy, and absence of
        stragglers in a handful of runs is weak evidence they never occur.
        """
        with self._lock:
            runs = self._straggle.runs
            prob = self._straggle.straggler_prob()
        if runs < min_runs:
            return default
        return max(prob, default / 2.0)

    def straggle_stats(self) -> StraggleStats:
        with self._lock:
            return dataclasses.replace(
                self._straggle, per_rank=dict(self._straggle.per_rank))


_ledger = CostLedger()


def ledger() -> CostLedger:
    """The process-global cost ledger."""
    return _ledger


def set_ledger(new: CostLedger) -> CostLedger:
    """Swap the global ledger (hermetic tests); returns the previous one."""
    global _ledger
    prev, _ledger = _ledger, new
    return prev
