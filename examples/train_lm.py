"""End-to-end training driver: any registered arch (reduced or full), AdamW
or the SPIN-Shampoo second-order optimizer (whose preconditioner inversions
run the paper's distributed Strassen solver).

    # ~100M-param LM, a few hundred steps (CPU-sized batches):
    PYTHONPATH=src python examples/train_lm.py --scale 100m --steps 200

    # quick CPU demo (~10M params):
    PYTHONPATH=src python examples/train_lm.py --scale 10m --steps 50

    # any assigned arch at reduced size, SPIN-Shampoo optimizer:
    PYTHONPATH=src python examples/train_lm.py --arch qwen2-moe-a2.7b \\
        --reduced --optimizer spin_shampoo --steps 20
"""

import argparse
import dataclasses
import json

import jax

from repro.configs import get_arch
from repro.configs.registry import ArchConfig
from repro.data.synthetic import TokenStream
from repro.runtime.trainer import TrainConfig, Trainer, init_state

SCALES = {
    # ~106M params: 10 x (4*640^2 attn + 3*640*2560 mlp) + 2*32000*640 embed
    "100m": ArchConfig(name="lm-100m", family="dense", n_layers=10,
                       d_model=640, n_heads=10, n_kv_heads=10, head_dim=64,
                       d_ff=2560, vocab=32000),
    "10m": ArchConfig(name="lm-10m", family="dense", n_layers=6,
                      d_model=256, n_heads=4, n_kv_heads=4, head_dim=64,
                      d_ff=1024, vocab=8192),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="registered arch id")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--scale", default="10m", choices=sorted(SCALES))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "spin_shampoo"])
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-json", default=None)
    args = ap.parse_args()

    if args.arch:
        cfg = get_arch(args.arch)
        if args.reduced:
            cfg = cfg.reduced()
    else:
        cfg = SCALES[args.scale]
    print(f"arch={cfg.name}  params≈{cfg.param_count() / 1e6:.1f}M  "
          f"optimizer={args.optimizer}")

    tcfg = TrainConfig(microbatches=args.microbatches,
                       optimizer=args.optimizer, warmup=10,
                       total_steps=max(args.steps, 100))
    stream = TokenStream(cfg, args.batch, args.seq, seed=0)
    state = init_state(cfg, tcfg, jax.random.PRNGKey(0), model_size_hint=1)
    trainer = Trainer(cfg, tcfg, stream, ckpt_dir=args.ckpt_dir,
                      ckpt_every=50)
    state = trainer.maybe_restore(state)
    state, logs = trainer.run(state, args.steps, log_every=10)
    print(f"final loss {logs[-1]['loss']:.4f} "
          f"(start {logs[0]['loss']:.4f}), "
          f"median step {sorted(l['dt'] for l in logs)[len(logs) // 2] * 1e3:.0f} ms")
    if args.log_json:
        with open(args.log_json, "w") as f:
            json.dump(logs, f)


if __name__ == "__main__":
    main()
