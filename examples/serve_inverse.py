"""Online inverse serving demo: a mutating ridge-regression workload.

The ridge normal equations w = (XᵀX + λI)⁻¹ Xᵀy are the paper's canonical
workload (examples/ridge_regression.py solves them ONCE). In production the
design matrix keeps growing: every new minibatch of k samples Xₖ is a
rank-k SPD update of the Gram matrix, G ← G + XₖᵀXₖ — exactly the churn
`serving.SpinService` maintains. This demo drives the service with an
interleaved stream of solve requests (fresh regression targets) and rank-k
Gram updates (arriving samples), and reports the request throughput plus
how the refactor policy split the updates between O(n²k) SMW folds and
planned re-factorizations.

    PYTHONPATH=src python examples/serve_inverse.py --features 512 \
        --requests 32 --update-rank 8

--sharded serves from a mesh-resident `ShardedBlockMatrix` pair (the
matrix AND its maintained inverse stay pinned to a 4×2 device mesh; run
under XLA_FLAGS=--xla_force_host_platform_device_count=8 to fake the
devices on CPU). The token-serving analogue of this loop — same slot
scheduler over a KV cache instead of an inverse — is examples/serve.py.
"""

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp

from repro.core import testing
from repro.serving import SpinService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=2048)
    ap.add_argument("--features", type=int, default=512)
    ap.add_argument("--lam", type=float, default=1e-2)
    ap.add_argument("--requests", type=int, default=32,
                    help="number of solve requests to stream")
    ap.add_argument("--update-rank", type=int, default=8,
                    help="samples per arriving minibatch (Gram update rank)")
    ap.add_argument("--update-every", type=int, default=4,
                    help="one Gram update per this many solve requests")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--block", type=int, default=None,
                    help="block size override (default: planner auto-tunes)")
    ap.add_argument("--sharded", action="store_true",
                    help="mesh-resident service state (ShardedBlockMatrix)")
    args = ap.parse_args()

    n = args.features
    key = jax.random.PRNGKey(0)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (args.samples, n)) / n ** 0.5
    w_true = jax.random.normal(kw, (n,))
    gram = x.T @ x + args.lam * jnp.eye(n)

    svc = SpinService(slots=args.slots)
    a0 = gram
    mesh_ctx = contextlib.nullcontext()
    if args.sharded:
        from repro.compat import AxisType, make_mesh, set_mesh

        devs = jax.devices()
        shape = (4, 2) if len(devs) >= 8 else (1, 1)
        mesh = make_mesh(shape, ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2,
                         devices=devs[:shape[0] * shape[1]])
        mesh_ctx = set_mesh(mesh)               # ambient for the whole run:
        # the service state is traced/constrained against THIS mesh, so
        # every later tick must run under the same context.
    with mesh_ctx:
        if args.sharded:
            from repro.parallel.sharded_blockmatrix import ShardedBlockMatrix
            from repro.planner import get_plan

            block = args.block or get_plan("inverse", n, jnp.float32,
                                           placement="sharded").block_size
            a0 = ShardedBlockMatrix.from_dense(gram, block)
        serve(svc, a0, args, x, w_true)


def serve(svc: SpinService, a0, args, x, w_true) -> None:
    n = args.features
    state = svc.add_matrix("gram", a0, block_size=args.block)
    print(f"admitted gram {n}x{n} [{state.placement}] block="
          f"{state.block_size} leaf={state.leaf_solver} "
          f"engine={state.engine}")

    solves, updates = [], []
    t0 = time.perf_counter()
    for i in range(args.requests):
        ky, kb = jax.random.split(jax.random.PRNGKey(10 + i))
        y = x @ w_true + 0.01 * jax.random.normal(ky, (args.samples,))
        solves.append(svc.solve("gram", x.T @ y))
        if args.update_every and (i + 1) % args.update_every == 0:
            xk = jax.random.normal(kb, (args.update_rank, n)) / n ** 0.5
            updates.append(svc.update("gram", xk.T))   # G += XₖᵀXₖ
        svc.tick()
    svc.run_until_done()
    for r in solves:
        jax.block_until_ready(r.x)
    dt = time.perf_counter() - t0

    assert all(r.done for r in solves + updates)
    # Correctness claim of the SERVICE: a solve submitted after the stream
    # drained answers the CURRENT (fully churned) normal equations — the
    # in-stream answers each solved their own barrier-consistent version.
    # Distance to w_true is reported but not asserted: arriving sample
    # batches carry no targets here, so they act as extra regularization
    # that legitimately biases w.
    probe = svc.solve("gram", solves[-1].rhs)
    svc.run_until_done()
    w_hat = probe.x
    a_now = state.a.to_dense() if state.placement == "sharded" else state.a
    resid = float(jnp.linalg.norm(a_now @ w_hat - probe.rhs)
                  / jnp.linalg.norm(probe.rhs))
    rel = float(jnp.linalg.norm(w_hat - w_true) / jnp.linalg.norm(w_true))
    smw = sum(1 for u in updates if not u.refactored)
    refac = sum(1 for u in updates if u.refactored)
    print(f"{args.requests} solves + {len(updates)} rank-{args.update_rank} "
          f"updates in {dt * 1e3:.0f} ms "
          f"({args.requests / dt:.1f} req/s, {svc.stats['batches']} batches,"
          f" {svc.stats['coalesced_cols']} coalesced cols)")
    print(f"updates: {smw} SMW folds, {refac} re-factorizations "
          f"(pending rank {state.pending_rank}, drift "
          f"{state.drift.residual_est:.2e} < {state.drift.tolerance:.0e})")
    print(f"last solve: normal-eq residual = {resid:.2e}  "
          f"||w-w*||/||w*|| = {rel:.2e}")
    assert resid < 1e-2


if __name__ == "__main__":
    main()
