"""Serving demo: batched prefill + decode with the KV/SSM cache.

    PYTHONPATH=src python examples/serve.py --arch hymba-1.5b --tokens 32

The matrix-inversion analogue of this loop — the same continuous-batching
slot scheduler serving solve/update requests against a maintained SPIN
inverse instead of tokens against a KV cache — is examples/serve_inverse.py
(`repro.serving.SpinService`, DESIGN.md §9).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import transformer as T


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    if not cfg.decode_capable:
        raise SystemExit(f"{args.arch} is encoder-only; no decode step")
    params = T.init_params(cfg, jax.random.PRNGKey(0), model_size_hint=1)

    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    max_len = args.prompt_len + args.tokens

    # ---- prefill: build the cache by streaming the prompt ------------------
    # (reduced CPU demo decodes the prompt token-by-token; on TPU the
    # prefill path processes the whole prompt in one forward)
    decode = jax.jit(lambda p, c, t: T.decode_step(p, c, t, cfg))
    cache = T.init_cache(cfg, args.batch, max_len)
    t0 = time.perf_counter()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = decode(params, cache, prompts[:, i])
    t_prefill = time.perf_counter() - t0

    # ---- greedy decode ------------------------------------------------------
    out = []
    tok = jnp.argmax(logits, axis=-1)
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.stack(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prompt ingest: {t_prefill * 1e3:.0f} ms; "
          f"decode: {args.tokens} tokens in {t_decode * 1e3:.0f} ms "
          f"({args.batch * args.tokens / t_decode:.1f} tok/s batched)")
    print("generated ids[0,:16]:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
