import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

# Distributed SPIN on a 4x4 device mesh (fake host devices on CPU; the same
# code runs on a real TPU mesh) with the double-buffered ring SUMMA engine,
# plus the TPU roofline projection for a production-scale inversion.
#
#     PYTHONPATH=src python examples/invert_at_scale.py --n 2048 --block 128

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import AxisType, make_mesh, set_mesh
from repro.core import (BlockMatrix, multiply_engine, spin_inverse, testing)
from repro.core.costmodel import tpu_roofline_cost
from repro.parallel import ShardedBlockMatrix, inverse_program
from repro.planner import get_plan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--block", type=int, default=None,
                    help="block size override (default: planner auto-tunes)")
    from repro.core.multiply import _ENGINES

    ap.add_argument("--engine", default=None, choices=list(_ENGINES),
                    help="multiply engine override (default: planner); "
                         "'pallas' is the fused-kernel engine (interpret "
                         "mode off-TPU), 'strassen' the recursive "
                         "7-multiply engine")
    ap.add_argument("--sharded", action="store_true",
                    help="mesh-resident recursion (spin_inverse_sharded): "
                         "every level's quadrants stay sharded over the "
                         "mesh, no inter-level gathers")
    args = ap.parse_args()

    mesh = make_mesh((4, 4), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2,
                     devices=jax.devices()[:16])
    # Plan INSIDE the mesh context: the signature then carries both the 16
    # (fake) devices — so the candidate space includes the allgather/ring
    # SUMMA engines — and the mesh topology, so the cached plan is keyed to
    # this (4, 4) mesh and never recalled for a different one.
    if args.block is None or args.engine is None:
        with set_mesh(mesh):
            plan = get_plan("inverse", args.n, jnp.float32,
                            placement="sharded" if args.sharded else "dense")
        block = args.block or plan.block_size
        engine = args.engine or plan.multiply_engine
        print(f"planner [{plan.source}]: block={plan.block_size} "
              f"engine={plan.multiply_engine} leaf={plan.leaf_solver}")
    else:
        block, engine = args.block, args.engine
    a = testing.make_spd(args.n, jax.random.PRNGKey(0))
    A = BlockMatrix.from_dense(a, block)
    print(f"n={args.n} grid={A.grid}x{A.grid} on mesh {dict(mesh.shape)} "
          f"engine={engine} path={'sharded' if args.sharded else 'dense'}")

    with set_mesh(mesh):
        sh = NamedSharding(mesh, P("data", "model", None, None))
        blocks = jax.device_put(A.blocks, sh)
        with multiply_engine(engine):
            if args.sharded:
                # one pjit program; quadrants stay mesh-resident per level
                f = lambda x: inverse_program(
                    ShardedBlockMatrix(x), engine=engine).blocks
            else:
                f = jax.jit(lambda x: spin_inverse(BlockMatrix(x)).blocks)
            jax.block_until_ready(f(blocks))      # compile
            t0 = time.perf_counter()
            inv = jax.block_until_ready(f(blocks))
            dt = time.perf_counter() - t0
    resid = jnp.linalg.norm(BlockMatrix(inv).to_dense() @ a
                            - jnp.eye(args.n)) / args.n ** 0.5
    print(f"inverted in {dt * 1e3:.0f} ms  residual {float(resid):.2e}")

    # what this would cost on the production pod (roofline projection)
    for n, b, chips in [(2 ** 17, 16, 256), (2 ** 18, 16, 256)]:
        r = tpu_roofline_cost(n=n, b=b, chips=chips)
        print(f"roofline n={n} b={b} chips={chips}: "
              f"compute {r['t_compute'] * 1e3:.1f} ms, "
              f"memory {r['t_memory'] * 1e3:.1f} ms, "
              f"collective {r['t_collective'] * 1e3:.1f} ms "
              f"-> bound: {r['bottleneck']}")


if __name__ == "__main__":
    main()
