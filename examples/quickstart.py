"""Quickstart: invert a matrix with SPIN, check accuracy, count the ops.

By default the planner (repro.planner) picks the block grid and leaf solver
from the paper's §4 cost model, refined by a short microbenchmark on small
problems; pass --block to override it by hand.

    PYTHONPATH=src python examples/quickstart.py [--n 1024] [--block 128]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (BlockMatrix, count_ops, lu_inverse_dense,
                        newton_schulz_polish, residual_norm, spin_inverse,
                        spin_inverse_dense, testing)
from repro.planner import get_plan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--block", type=int, default=None,
                    help="block size override (default: planner auto-tunes)")
    args = ap.parse_args()

    a = testing.make_spd(args.n, jax.random.PRNGKey(0))

    if args.block is None:
        plan = get_plan("inverse", args.n, a.dtype)
        block, leaf = plan.block_size, plan.leaf_solver
        print(f"planner [{plan.source}]: block={block} "
              f"(grid {args.n // block}x{args.n // block}) leaf={leaf} "
              f"engine={plan.multiply_engine}")
    else:
        block, leaf = args.block, "linalg"
        print(f"explicit override: block={block} "
              f"(grid {args.n // block}x{args.n // block})")
    print(f"SPD test matrix n={args.n}, block={block}")

    # --- SPIN (the paper's algorithm) -------------------------------------
    t0 = time.perf_counter()
    inv = jax.block_until_ready(spin_inverse_dense(a, block, leaf))
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    inv = jax.block_until_ready(spin_inverse_dense(a, block, leaf))
    t_spin = time.perf_counter() - t0
    resid = jnp.linalg.norm(inv @ a - jnp.eye(args.n)) / args.n ** 0.5
    print(f"SPIN:  {t_spin * 1e3:8.1f} ms   ||AX-I||/sqrt(n) = {resid:.2e} "
          f"(first call incl. compile: {t_compile * 1e3:.0f} ms)")

    # --- LU baseline (Liu et al., the paper's comparison) ------------------
    _ = jax.block_until_ready(lu_inverse_dense(a, block))
    t0 = time.perf_counter()
    _ = jax.block_until_ready(lu_inverse_dense(a, block))
    t_lu = time.perf_counter() - t0
    print(f"LU:    {t_lu * 1e3:8.1f} ms   -> SPIN speedup {t_lu / t_spin:.2f}x")

    # --- op accounting (the paper's Table 1 claim) -------------------------
    A = BlockMatrix.from_dense(a, block)
    with count_ops() as spin_ops:
        x = spin_inverse(A)
    print(f"SPIN distributed multiplies: {spin_ops.multiplies} "
          f"(6 per recursion node), leaf inversions: {spin_ops.leaf_inversions}")

    # --- optional Newton–Schulz polish -------------------------------------
    polished = newton_schulz_polish(A, x, sweeps=1)
    print(f"residual after 1 Newton–Schulz sweep: "
          f"{float(residual_norm(A, polished)):.2e}")


if __name__ == "__main__":
    main()
