"""Quickstart: invert a matrix with SPIN, check accuracy, count the ops.

    PYTHONPATH=src python examples/quickstart.py [--n 1024] [--block 128]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (BlockMatrix, count_ops, lu_inverse_dense,
                        newton_schulz_polish, residual_norm, spin_inverse,
                        spin_inverse_dense, testing)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--block", type=int, default=128)
    args = ap.parse_args()

    print(f"SPD test matrix n={args.n}, block={args.block} "
          f"(grid {args.n // args.block}x{args.n // args.block})")
    a = testing.make_spd(args.n, jax.random.PRNGKey(0))

    # --- SPIN (the paper's algorithm) -------------------------------------
    t0 = time.perf_counter()
    inv = jax.block_until_ready(spin_inverse_dense(a, args.block))
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    inv = jax.block_until_ready(spin_inverse_dense(a, args.block))
    t_spin = time.perf_counter() - t0
    resid = jnp.linalg.norm(inv @ a - jnp.eye(args.n)) / args.n ** 0.5
    print(f"SPIN:  {t_spin * 1e3:8.1f} ms   ||AX-I||/sqrt(n) = {resid:.2e} "
          f"(first call incl. compile: {t_compile * 1e3:.0f} ms)")

    # --- LU baseline (Liu et al., the paper's comparison) ------------------
    _ = jax.block_until_ready(lu_inverse_dense(a, args.block))
    t0 = time.perf_counter()
    _ = jax.block_until_ready(lu_inverse_dense(a, args.block))
    t_lu = time.perf_counter() - t0
    print(f"LU:    {t_lu * 1e3:8.1f} ms   -> SPIN speedup {t_lu / t_spin:.2f}x")

    # --- op accounting (the paper's Table 1 claim) -------------------------
    A = BlockMatrix.from_dense(a, args.block)
    with count_ops() as spin_ops:
        x = spin_inverse(A)
    print(f"SPIN distributed multiplies: {spin_ops.multiplies} "
          f"(6 per recursion node), leaf inversions: {spin_ops.leaf_inversions}")

    # --- optional Newton–Schulz polish -------------------------------------
    polished = newton_schulz_polish(A, x, sweeps=1)
    print(f"residual after 1 Newton–Schulz sweep: "
          f"{float(residual_norm(A, polished)):.2e}")


if __name__ == "__main__":
    main()
