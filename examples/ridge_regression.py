"""Application example: distributed ridge regression via SPIN.

The paper motivates matrix inversion with Data/Earth-science workloads;
ridge regression is the canonical one:  w = (XᵀX + λI)⁻¹ Xᵀ y.
The Gram matrix is assembled as a BlockMatrix and the normal equations are
SOLVED with `spin_solve` — the inverse-free path through the paper's
recursion (A⁻¹ is never materialized; for one RHS that skips half the
quadrant multiplies). `--multi-target` demonstrates the multi-RHS case
(one solve for many regression targets), and `--inverse` keeps the original
invert-then-multiply path for comparison. The block grid is autotuned by
the planner unless --block overrides it.

    PYTHONPATH=src python examples/ridge_regression.py --features 1024
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (BlockMatrix, newton_schulz_polish, spin_inverse,
                        spin_solve)
from repro.planner import get_plan


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--features", type=int, default=1024)
    ap.add_argument("--block", type=int, default=None,
                    help="block size override (default: planner auto-tunes)")
    ap.add_argument("--lam", type=float, default=1e-2)
    ap.add_argument("--multi-target", type=int, default=1,
                    help="number of regression targets (multi-RHS solve)")
    ap.add_argument("--inverse", action="store_true",
                    help="materialize A^-1 then multiply (original path)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    kx, kw, kn = jax.random.split(key, 3)
    x = jax.random.normal(kx, (args.samples, args.features)) / \
        args.features ** 0.5
    w_true = jax.random.normal(kw, (args.features, args.multi_target))
    y = x @ w_true + 0.01 * jax.random.normal(
        kn, (args.samples, args.multi_target))

    gram = x.T @ x + args.lam * jnp.eye(args.features)
    rhs = x.T @ y                                  # (features, targets)

    if args.block is None:
        kind = "inverse" if args.inverse else "solve"
        plan = get_plan(kind, args.features, gram.dtype)
        block = plan.block_size
        print(f"planner [{plan.source}]: block={block} "
              f"(grid {args.features // block}) leaf={plan.leaf_solver}")
    else:
        block = args.block

    t0 = time.perf_counter()
    a = BlockMatrix.from_dense(gram, block)
    if args.inverse:
        inv = spin_inverse(a)
        inv = newton_schulz_polish(a, inv, sweeps=1)
        w_hat = inv.to_dense() @ rhs
    else:
        w_hat = spin_solve(a, rhs)
    jax.block_until_ready(w_hat)
    dt = time.perf_counter() - t0

    rel = float(jnp.linalg.norm(w_hat - w_true) / jnp.linalg.norm(w_true))
    resid = float(jnp.linalg.norm(gram @ w_hat - rhs) /
                  jnp.linalg.norm(rhs))
    mode = "inverse+NS" if args.inverse else "spin_solve"
    print(f"ridge {args.samples}x{args.features} "
          f"targets={args.multi_target} [{mode}]: solved in {dt * 1e3:.0f} ms"
          f"  ||w-w*||/||w*||={rel:.2e}  normal-eq residual={resid:.2e}")
    assert resid < 1e-3


if __name__ == "__main__":
    main()
