"""Application example: distributed ridge regression via SPIN.

The paper motivates matrix inversion with Data/Earth-science workloads;
ridge regression is the canonical one:  w = (XᵀX + λI)⁻¹ Xᵀ y.
The Gram matrix is assembled as a BlockMatrix and inverted with the
paper's algorithm (optionally on a device mesh — same code).

    PYTHONPATH=src python examples/ridge_regression.py --features 1024
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import BlockMatrix, newton_schulz_polish, spin_inverse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--features", type=int, default=1024)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--lam", type=float, default=1e-2)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    kx, kw, kn = jax.random.split(key, 3)
    x = jax.random.normal(kx, (args.samples, args.features)) / \
        args.features ** 0.5
    w_true = jax.random.normal(kw, (args.features,))
    y = x @ w_true + 0.01 * jax.random.normal(kn, (args.samples,))

    gram = x.T @ x + args.lam * jnp.eye(args.features)
    rhs = x.T @ y

    t0 = time.perf_counter()
    a = BlockMatrix.from_dense(gram, args.block)
    inv = spin_inverse(a)
    inv = newton_schulz_polish(a, inv, sweeps=1)
    w_hat = inv.to_dense() @ rhs
    jax.block_until_ready(w_hat)
    dt = time.perf_counter() - t0

    rel = float(jnp.linalg.norm(w_hat - w_true) / jnp.linalg.norm(w_true))
    resid = float(jnp.linalg.norm(gram @ w_hat - rhs) /
                  jnp.linalg.norm(rhs))
    print(f"ridge {args.samples}x{args.features}: solved in {dt * 1e3:.0f} ms"
          f"  ||w-w*||/||w*||={rel:.2e}  normal-eq residual={resid:.2e}")
    assert resid < 1e-3


if __name__ == "__main__":
    main()
